"""Continuous-batching scheduler: priority queue, admission, completion.

The serving pattern the paper measures (vLLM on cGPU, IPEX batched decode on
CPU TEEs): requests arrive asynchronously, prefill claims a free slot,
decode advances all active slots each step, finished sequences free their
slot immediately for the next queued request. Tracks the user-perceived
metrics from §III-C: throughput (tokens/s), next-token latency, and
time-to-first-token.

v2 additions:
  * requests carry a ``priority`` — admission pops the highest-priority
    waiting request (FIFO within a priority level), and the engine may
    preempt a lower-priority running slot via sealed-KV eviction (§V-D3);
  * ``on_token`` streaming callback — fired the moment a token is recorded,
    i.e. right after it crossed the trust boundary as an encrypted frame;
  * ``pending_input`` holds the not-yet-prefilled tail of a long prompt so
    chunked prefill state travels with the request through seal/restore.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Dict, List, Optional

import numpy as np

TokenCallback = Callable[["Request", int], None]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # int32 [prompt_len]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    priority: int = 0                  # higher = more important
    on_token: Optional[TokenCallback] = None
    # filled during serving
    output: List[int] = dataclasses.field(default_factory=list)
    pending_input: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    token_times: List[float] = dataclasses.field(default_factory=list)
    n_preemptions: int = 0
    seal_epoch: int = 0    # bumps on every sealed-KV eviction (nonce freshness)
    stream_id: int = -1    # channel-global egress stream (set by the engine)

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.output and self.output[-1] == self.eos_id:
            return True
        return len(self.output) >= self.max_new_tokens

    @property
    def finished(self) -> bool:
        return self.t_done > 0.0


@dataclasses.dataclass
class ServeStats:
    total_tokens: int = 0
    total_requests: int = 0
    wall_s: float = 0.0
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    ttft_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def throughput_tps(self) -> float:
        return self.total_tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def mean_latency_s(self) -> float:
        return float(np.mean(self.latencies_s)) if self.latencies_s else 0.0

    @property
    def p99_latency_s(self) -> float:
        return float(np.percentile(self.latencies_s, 99)) if self.latencies_s else 0.0

    @property
    def mean_ttft_s(self) -> float:
        return float(np.mean(self.ttft_s)) if self.ttft_s else 0.0

    @property
    def p99_ttft_s(self) -> float:
        return float(np.percentile(self.ttft_s, 99)) if self.ttft_s else 0.0


class Scheduler:
    def __init__(self):
        # waiting heap entries: (-priority, rid, Request) — rid ties keep
        # submission order within a priority level, and survive requeueing.
        self.queue: List[tuple] = []
        self.running: Dict[int, Request] = {}   # slot -> request
        self.finished: List[Request] = []
        self._next_rid = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None, *, priority: int = 0,
               on_token: Optional[TokenCallback] = None) -> Request:
        req = Request(self._next_rid, np.asarray(prompt, np.int32),
                      max_new_tokens, eos_id, priority=priority,
                      on_token=on_token, t_submit=time.monotonic())
        self._next_rid += 1
        heapq.heappush(self.queue, (-req.priority, req.rid, req))
        return req

    def peek_waiting(self) -> Optional[Request]:
        return self.queue[0][2] if self.queue else None

    def next_waiting(self) -> Optional[Request]:
        return heapq.heappop(self.queue)[2] if self.queue else None

    def start(self, slot: int, req: Request) -> None:
        self.running[slot] = req

    def record_token(self, slot: int, token: int) -> None:
        req = self.running[slot]
        now = time.monotonic()
        if not req.output:
            req.t_first_token = now
        req.output.append(int(token))
        req.token_times.append(now)
        if req.on_token is not None:
            req.on_token(req, int(token))

    def finish(self, slot: int) -> Request:
        req = self.running.pop(slot)
        req.t_done = time.monotonic()
        self.finished.append(req)
        return req

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running

    def stats(self) -> ServeStats:
        return stats_from_requests(self.finished)


def stats_from_requests(reqs: List[Request]) -> ServeStats:
    """ServeStats over any set of finished requests (benchmarks measure a
    warm wave this way, excluding an earlier compile-warmup wave)."""
    s = ServeStats()
    done = [r for r in reqs if r.finished]
    if not done:
        return s
    t0 = min(r.t_submit for r in done)
    t1 = max(r.t_done for r in done)
    s.wall_s = t1 - t0
    s.total_requests = len(done)
    for r in done:
        s.total_tokens += len(r.output)
        s.ttft_s.append(r.t_first_token - r.t_submit)
        # inter-token gaps only: token_times[0] IS the first-token time, so
        # prepending t_first_token would inject a spurious 0.0 latency that
        # deflates the mean/p99 this repo exists to measure.
        s.latencies_s.extend(float(b - a) for a, b in
                             zip(r.token_times[:-1], r.token_times[1:]))
    return s
