"""Continuous-batching scheduler: request queue, admission, completion.

The serving pattern the paper measures (vLLM on cGPU, IPEX batched decode on
CPU TEEs): requests arrive asynchronously, prefill claims a free slot,
decode advances all active slots each step, finished sequences free their
slot immediately for the next queued request. Tracks the two user-perceived
metrics from §III-C: throughput (tokens/s) and next-token latency.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # int32 [prompt_len]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled during serving
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.output and self.output[-1] == self.eos_id:
            return True
        return len(self.output) >= self.max_new_tokens


@dataclasses.dataclass
class ServeStats:
    total_tokens: int = 0
    total_requests: int = 0
    wall_s: float = 0.0
    latencies_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def throughput_tps(self) -> float:
        return self.total_tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def mean_latency_s(self) -> float:
        return float(np.mean(self.latencies_s)) if self.latencies_s else 0.0

    @property
    def p99_latency_s(self) -> float:
        return float(np.percentile(self.latencies_s, 99)) if self.latencies_s else 0.0


class Scheduler:
    def __init__(self):
        self.queue: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}   # slot -> request
        self.finished: List[Request] = []
        self._next_rid = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> Request:
        req = Request(self._next_rid, np.asarray(prompt, np.int32),
                      max_new_tokens, eos_id, t_submit=time.monotonic())
        self._next_rid += 1
        self.queue.append(req)
        return req

    def next_waiting(self) -> Optional[Request]:
        return self.queue.popleft() if self.queue else None

    def start(self, slot: int, req: Request) -> None:
        self.running[slot] = req

    def record_token(self, slot: int, token: int) -> None:
        req = self.running[slot]
        now = time.monotonic()
        if not req.output:
            req.t_first_token = now
        req.output.append(int(token))
        req.token_times.append(now)

    def finish(self, slot: int) -> Request:
        req = self.running.pop(slot)
        req.t_done = time.monotonic()
        self.finished.append(req)
        return req

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running

    def stats(self) -> ServeStats:
        s = ServeStats()
        if not self.finished:
            return s
        t0 = min(r.t_submit for r in self.finished)
        t1 = max(r.t_done for r in self.finished)
        s.wall_s = t1 - t0
        s.total_requests = len(self.finished)
        for r in self.finished:
            s.total_tokens += len(r.output)
            times = [r.t_first_token] + r.token_times
            s.latencies_s.extend(float(b - a) for a, b in zip(times[:-1], times[1:]))
        return s
