"""Continuous-batching scheduler: priority queue, admission, completion.

The serving pattern the paper measures (vLLM on cGPU, IPEX batched decode on
CPU TEEs): requests arrive asynchronously, prefill claims a free slot,
decode advances all active slots each step, finished sequences free their
slot immediately for the next queued request. Tracks the user-perceived
metrics from §III-C: throughput (tokens/s), next-token latency, and
time-to-first-token.

v3 (request-object API): the scheduler speaks
:class:`~repro.runtime.api.GenerationRequest` — per-request sampling, frame
policy and SLO fields live on the submitted object, not in a kwargs bag
duplicated here and in the engine. :class:`Request` is the live serving
record wrapped around it (output, timing, seal/stream state) and converts
to a :class:`~repro.runtime.api.RequestOutput` on completion.

SLO machinery:
  * the waiting queue orders by **slack** first (``order="slack"``, the
    default): a request's slack ``deadline_s - elapsed`` shrinks as it
    waits, but ``t_submit + deadline_s`` — its absolute deadline — is
    time-invariant, so earliest-absolute-deadline IS the
    tightest-slack-first order and keeps heap keys static. Priority breaks
    ties (and deadline-less requests, whose slack is infinite, keep their
    pure priority-then-arrival order among themselves). The point:
    deadline-bound requests are served while their deadline is still
    meetable, so ``on_deadline="abort"`` fires rarely instead of cheaply.
    ``order="priority"`` restores the v4 priority-only ordering (the
    baseline the forced-contention test measures the abort reduction
    against). Preemption is untouched — only strict *priority* ever evicts
    a running slot;
  * ``drop_expired`` removes queued requests whose relative deadline has
    passed (``on_deadline="drop"`` or ``"abort"``) before they waste
    prefill compute; ``abort_expired`` additionally marks *mid-flight*
    requests for engine-side termination (seal/discard, not restore);
  * ``peek_waiting``/``next_waiting`` accept an admissibility predicate so
    the engine's per-priority token-rate budgets can hold a class back
    without starving the others — and so a continuous-batching engine can
    *backfill*: when the head's prefill bucket doesn't fit the remaining
    step-token budget, :meth:`Scheduler.next_backfill` hands out the best
    queued request that does fit, keeping the step saturated without
    reordering anything the head could still claim next step;
  * every :class:`Request` carries a coarse serving ``phase``
    (queued → prefill → decode → done): under disaggregated serving,
    prefill and decode are independently scheduled phases and a request in
    ``phase="prefill"`` is in flight on the prefill plan, its KV not yet
    handed off to the decode plan (``n_handoffs``/``handoff_bytes`` price
    that sealed crossing per request);
  * :class:`ServeStats` reports p50 alongside mean/p99 (percentiles guarded
    for <2 samples) plus dropped/deadline-miss/preemption counters, making
    the preemption-vs-drop trade-off measurable.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.runtime.api import (FINISH_ABORTED, FINISH_DROPPED, FINISH_LENGTH,
                               FINISH_REJECTED, FINISH_STOP, GenerationRequest,
                               RequestOutput, TokenCallback)

AdmitPredicate = Callable[["Request"], bool]


@dataclasses.dataclass
class Request:
    """Live serving record for one submitted :class:`GenerationRequest`."""
    rid: int
    gen: GenerationRequest
    # filled during serving
    output: List[int] = dataclasses.field(default_factory=list)
    pending_input: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    token_times: List[float] = dataclasses.field(default_factory=list)
    finish_reason: str = ""
    n_preemptions: int = 0
    kv_need: int = 0       # worst-case KV positions (set at submit; the unit
                           # the KV backend's admission accounting charges —
                           # *effective*, i.e. net of resident shared pages,
                           # on a prefix-sharing backend)
    page_keys: Optional[list] = None   # prompt page content keys (sharing)
    sealed_pages: int = 0  # pages held at the last whole-slot seal (what an
                           # on-demand pool gates the restore on)
    sealed_bytes: int = 0  # ciphertext bytes this request's evictions moved
    seal_epoch: int = 0    # bumps on every sealed-KV eviction (nonce freshness)
    stream_id: int = -1    # channel-global egress stream (set by the engine)
    seed: Optional[int] = None          # resolved sampling seed (reproducible)
    egress_buf: List[int] = dataclasses.field(default_factory=list)
    ingress_messages: int = 0
    egress_frames: int = 0
    egress_tokens: int = 0
    # -- two-phase serving (continuous batching / disaggregated prefill) ----
    phase: str = "queued"  # "queued" | "prefill" | "decode" | "done"
    n_handoffs: int = 0    # sealed prefill->decode plan handoffs
    handoff_bytes: int = 0  # ciphertext bytes those handoffs moved
    backfilled: bool = False  # admitted out of queue order into leftover
                              # step-token budget (continuous batching)
    # -- fleet serving -------------------------------------------------------
    n_migrations: int = 0   # sealed cross-worker moves (drain/failure)
    migrated_bytes: int = 0  # ciphertext bytes those migrations carried

    # -- mirrors of the generation request (single source of truth: gen) ----
    @property
    def prompt(self) -> np.ndarray:
        return self.gen.prompt

    @property
    def max_new_tokens(self) -> int:
        return self.gen.max_new_tokens

    @property
    def eos_id(self) -> Optional[int]:
        return self.gen.eos_id

    @property
    def priority(self) -> int:
        return self.gen.priority

    @property
    def on_token(self) -> Optional[TokenCallback]:
        return self.gen.on_token

    @property
    def coalesce(self) -> int:
        return self.gen.frame.coalesce

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.output and self.output[-1] == self.eos_id:
            return True
        return len(self.output) >= self.max_new_tokens

    @property
    def finished(self) -> bool:
        return self.t_done > 0.0

    @property
    def dropped(self) -> bool:
        return self.finish_reason == FINISH_DROPPED

    @property
    def aborted(self) -> bool:
        return self.finish_reason == FINISH_ABORTED

    @property
    def rejected(self) -> bool:
        return self.finish_reason == FINISH_REJECTED

    @property
    def abs_deadline(self) -> float:
        """Absolute deadline (monotonic clock); inf when none. Static per
        request, which is what makes slack ordering heap-safe."""
        if self.gen.deadline_s is None:
            return float("inf")
        return self.t_submit + self.gen.deadline_s

    @property
    def deadline_missed(self) -> bool:
        return (not self.dropped and self.finished
                and self.gen.deadline_s is not None
                and self.t_done - self.t_submit > self.gen.deadline_s)

    def expired(self, now: float) -> bool:
        """True when a still-queued request should be dropped (deadline SLO).
        ``abort`` subsumes ``drop`` while queued — a request that would be
        killed mid-flight is certainly not worth starting late."""
        return (self.gen.deadline_s is not None
                and self.gen.on_deadline in ("drop", "abort")
                and now - self.t_submit > self.gen.deadline_s)

    def abort_expired(self, now: float) -> bool:
        """True when a mid-flight request should be aborted (seal/discard)."""
        return (self.gen.deadline_s is not None
                and self.gen.on_deadline == "abort"
                and now - self.t_submit > self.gen.deadline_s)

    def result(self) -> RequestOutput:
        """The finished request as an API-level :class:`RequestOutput`."""
        return RequestOutput.from_request(self)


@dataclasses.dataclass
class ServeStats:
    total_tokens: int = 0
    total_requests: int = 0
    dropped_requests: int = 0      # deadline passed while queued (on_deadline=drop)
    aborted_requests: int = 0      # terminated mid-flight (on_deadline=abort)
    rejected_infeasible: int = 0   # refused at ingest: deadline unmeetable
    deadline_misses: int = 0       # served, but finished after deadline_s
    preemptions: int = 0           # sealed-KV evictions among served requests
    sealed_bytes: int = 0          # ciphertext bytes those evictions moved
    handoffs: int = 0              # sealed prefill->decode plan handoffs
    handoff_bytes: int = 0         # ciphertext bytes those handoffs moved
    backfilled_requests: int = 0   # admitted via continuous-batching backfill
    migrations: int = 0            # sealed cross-worker KV moves (fleet)
    migrated_bytes: int = 0        # ciphertext bytes those migrations carried
    shared_pages: int = 0          # page mappings served by the prefix index
    cow_copies: int = 0            # shared tail pages copied on first write
    store_hits: int = 0            # pages restored from the sealed store
    store_restored_bytes: int = 0  # ciphertext bytes those hits moved back
    store_evictions: int = 0       # store pages shed by retention policy
    wall_s: float = 0.0
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    ttft_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def throughput_tps(self) -> float:
        return self.total_tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def mean_latency_s(self) -> float:
        return float(np.mean(self.latencies_s)) if self.latencies_s else 0.0

    @property
    def p50_latency_s(self) -> float:
        return _pct(self.latencies_s, 50)

    @property
    def p99_latency_s(self) -> float:
        return _pct(self.latencies_s, 99)

    @property
    def mean_ttft_s(self) -> float:
        return float(np.mean(self.ttft_s)) if self.ttft_s else 0.0

    @property
    def p50_ttft_s(self) -> float:
        return _pct(self.ttft_s, 50)

    @property
    def p99_ttft_s(self) -> float:
        return _pct(self.ttft_s, 99)


def _pct(xs: Sequence[float], q: float) -> float:
    """Percentile guarded for tiny samples: with fewer than 2 observations a
    percentile is not an estimate, it's the sample (or nothing)."""
    if not xs:
        return 0.0
    if len(xs) < 2:
        return float(xs[0])
    return float(np.percentile(xs, q))


class Scheduler:
    def __init__(self, order: str = "slack"):
        # waiting heap entries: (key, rid, Request) — rid ties keep
        # submission order, and survive requeueing. The key is
        # (abs_deadline, -priority) in slack order (tightest deadline first,
        # priority tiebreak) or (-priority,) in priority order.
        if order not in ("slack", "priority"):
            raise ValueError(
                f"order must be 'slack' or 'priority', got {order!r}")
        self.order = order
        self.queue: List[tuple] = []
        self.running: Dict[int, Request] = {}   # slot -> request
        self.finished: List[Request] = []
        self.dropped: List[Request] = []
        self._next_rid = 0

    def _key(self, req: Request) -> tuple:
        if self.order == "slack":
            return (req.abs_deadline, -req.priority)
        return (-req.priority,)

    def submit(self, gen: GenerationRequest) -> Request:
        req = Request(self._next_rid, gen, t_submit=time.monotonic())
        self._next_rid += 1
        heapq.heappush(self.queue, (self._key(req), req.rid, req))
        return req

    def reject(self, gen: GenerationRequest) -> Request:
        """Refuse a request at ingest (admission-time deadline feasibility):
        the request never enters the queue, holds no stream/slot/page, and
        finishes immediately with ``finish_reason="rejected"``. Cheaper for
        everyone than aborting it mid-decode after it consumed prefill
        compute and sealed-KV bandwidth."""
        req = Request(self._next_rid, gen, t_submit=time.monotonic())
        self._next_rid += 1
        req.finish_reason = FINISH_REJECTED
        req.t_done = req.t_submit
        req.phase = "done"
        self.dropped.append(req)
        return req

    def drop_expired(self, now: Optional[float] = None) -> List[Request]:
        """Remove queued requests whose drop-deadline has passed. Returns the
        dropped requests (the engine still owns their stream teardown)."""
        if not any(req.expired(now or time.monotonic())
                   for _, _, req in self.queue):
            return []
        now = now or time.monotonic()
        kept, dropped = [], []
        for entry in self.queue:
            (dropped if entry[2].expired(now) else kept).append(entry)
        heapq.heapify(kept)
        self.queue = kept
        out = []
        for _, _, req in sorted(dropped, key=lambda e: e[1]):
            req.finish_reason = FINISH_DROPPED
            req.t_done = now
            self.dropped.append(req)
            out.append(req)
        return out

    def peek_waiting(self, admissible: Optional[AdmitPredicate] = None
                     ) -> Optional[Request]:
        """Best-ordered waiting request (tightest slack first in the default
        order, then priority), optionally skipping entries the predicate
        rejects (e.g. a priority class over its token-rate budget)."""
        if admissible is None:
            return self.queue[0][2] if self.queue else None
        for _, _, req in sorted(self.queue):
            if admissible(req):
                return req
        return None

    def peek_priority(self, admissible: Optional[AdmitPredicate] = None
                      ) -> Optional[Request]:
        """The highest-PRIORITY waiting request regardless of queue order —
        the gatekeeper for restore/preemption decisions. In slack order the
        queue head is the tightest *deadline* (possibly low priority), but
        priority gates must still see the strongest waiting contender, or a
        deadline-less high-priority request could neither block restores of
        weaker sealed work nor exercise its preemption right. In priority
        order this coincides with :meth:`peek_waiting`."""
        best = None
        for _, _, req in self.queue:
            if admissible is not None and not admissible(req):
                continue
            if best is None or (req.priority, -req.rid) > (best.priority,
                                                           -best.rid):
                best = req
        return best

    def next_waiting(self, admissible: Optional[AdmitPredicate] = None
                     ) -> Optional[Request]:
        if admissible is None:
            return heapq.heappop(self.queue)[2] if self.queue else None
        for entry in sorted(self.queue):
            if admissible(entry[2]):
                self.queue.remove(entry)
                heapq.heapify(self.queue)
                return entry[2]
        return None

    def next_backfill(self, fits: AdmitPredicate) -> Optional[Request]:
        """Pop the best-ordered waiting request satisfying ``fits`` — the
        continuous-batching backfill path. Identical mechanics to
        :meth:`next_waiting` with a predicate; named separately because the
        *caller's* contract differs: the predicate excludes the queue head
        (which keeps first claim on next step's fresh budget), so anything
        returned here is an out-of-order admission the caller must flag
        (``Request.backfilled``)."""
        return self.next_waiting(fits)

    def start(self, slot: int, req: Request) -> None:
        self.running[slot] = req

    def record_token(self, slot: int, token: int) -> None:
        """Record one sampled (plaintext, in-domain) token. Egress/stream
        callbacks are the engine's job — they happen at frame-flush time."""
        req = self.running[slot]
        now = time.monotonic()
        if not req.output:
            req.t_first_token = now
        req.output.append(int(token))
        req.token_times.append(now)

    def finish(self, slot: int) -> Request:
        req = self.running.pop(slot)
        req.t_done = time.monotonic()
        req.phase = "done"
        if not req.finish_reason:
            req.finish_reason = (
                FINISH_STOP if (req.eos_id is not None and req.output
                                and req.output[-1] == req.eos_id)
                else FINISH_LENGTH)
        self.finished.append(req)
        return req

    def finish_detached(self, req: Request) -> Request:
        """Finish a request that holds no slot (e.g. a sealed-out preempted
        request being aborted instead of restored). The caller sets
        ``finish_reason`` first."""
        req.t_done = time.monotonic()
        req.phase = "done"
        self.finished.append(req)
        return req

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running

    def stats(self) -> ServeStats:
        return stats_from_requests(self.finished + self.dropped)


def stats_from_requests(reqs: List[Request]) -> ServeStats:
    """ServeStats over any set of finished requests (benchmarks measure a
    warm wave this way, excluding an earlier compile-warmup wave). Dropped
    requests count toward ``dropped_requests`` but contribute no tokens or
    latency samples — they never produced any."""
    s = ServeStats()
    done = [r for r in reqs
            if r.finished and not r.dropped and not r.rejected]
    s.dropped_requests = sum(1 for r in reqs if r.dropped)
    s.rejected_infeasible = sum(1 for r in reqs if r.rejected)
    if not done:
        return s
    t0 = min(r.t_submit for r in done)
    t1 = max(r.t_done for r in done)
    s.wall_s = t1 - t0
    s.total_requests = len(done)
    for r in done:
        s.total_tokens += len(r.output)
        s.preemptions += r.n_preemptions
        s.sealed_bytes += r.sealed_bytes
        s.handoffs += r.n_handoffs
        s.handoff_bytes += r.handoff_bytes
        s.backfilled_requests += int(r.backfilled)
        s.migrations += r.n_migrations
        s.migrated_bytes += r.migrated_bytes
        s.aborted_requests += int(r.aborted)
        s.deadline_misses += int(r.deadline_missed)
        if r.output:   # an aborted request may die before its first token
            s.ttft_s.append(r.t_first_token - r.t_submit)
        # inter-token gaps only: token_times[0] IS the first-token time, so
        # prepending t_first_token would inject a spurious 0.0 latency that
        # deflates the mean/p99 this repo exists to measure.
        s.latencies_s.extend(float(b - a) for a, b in
                             zip(r.token_times[:-1], r.token_times[1:]))
    return s
