"""Training data pipeline: deterministic synthetic corpus + file corpus,
packed into fixed-length LM batches with next-token labels.

Deterministic by construction (seeded), so restart-resume tests can assert
bitwise-identical loss curves after a simulated failure.
"""

from __future__ import annotations

import itertools
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.tokenizer import ByteTokenizer

_WORDS = (
    "confidential inference enclave attestation throughput latency batch "
    "tensor trusted execution environment memory encryption keystream "
    "roofline collective shard pipeline expert decode prefill token cache "
    "llama whisper jamba rwkv deepseek qwen mistral chameleon dbrx model"
).split()


def synthetic_text(seed: int, n_sentences: int = 1000) -> str:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_sentences):
        n = int(rng.integers(4, 12))
        out.append(" ".join(rng.choice(_WORDS, n)) + ".")
    return " ".join(out)


class PackedLMDataset:
    """Infinite iterator of {"tokens": [b, s], "labels": [b, s]} int32."""

    def __init__(self, text: Optional[str] = None, *, path: Optional[str] = None,
                 batch_size: int = 8, seq_len: int = 128, seed: int = 0):
        self.tok = ByteTokenizer()
        if path is not None:
            text = Path(path).read_text()
        if text is None:
            text = synthetic_text(seed)
        ids = self.tok.encode(text, bos=False)
        # pack into one long stream, wrap around
        need = batch_size * (seq_len + 1)
        reps = max(1, -(-need // len(ids)))
        self.stream = np.tile(ids, reps + 1)
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        self._cursor = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b, s = self.batch_size, self.seq_len
        rows = []
        for _ in range(b):
            start = self._cursor % (len(self.stream) - s - 1)
            rows.append(self.stream[start:start + s + 1])
            self._cursor += s + 1
        arr = np.stack(rows).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def state(self) -> int:
        return self._cursor

    def restore(self, cursor: int) -> None:
        self._cursor = cursor


def take(it, n: int):
    return list(itertools.islice(it, n))
