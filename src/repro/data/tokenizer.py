"""Byte-level tokenizer (vocab 256 + specials) — self-contained data path."""

from __future__ import annotations

from typing import List

import numpy as np

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return np.asarray(ids, np.int32)

    def decode(self, ids: List[int] | np.ndarray) -> str:
        raw = bytes(int(i) for i in np.asarray(ids).reshape(-1)
                    if 0 <= int(i) < 256)
        return raw.decode("utf-8", errors="replace")
