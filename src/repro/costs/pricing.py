"""Hardware & pricing catalog for the cost model (paper §V-D2, Figs 12-13).

CPU prices follow the paper's GCP spot methodology (per-vCPU + per-GB
pricing, US East 1); GPU/TPU prices are representative on-demand cloud
rates. All $ figures are parameters, not facts about today's market — the
cost *model* (crossover structure) is the contribution being reproduced.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HardwareSKU:
    name: str
    kind: str                      # "cpu" | "gpu" | "tpu"
    peak_flops: float              # bf16/int8-effective FLOP/s
    mem_bw: float                  # bytes/s
    mem_bytes: float
    usd_per_hour: float            # base (cGPU/TPU: whole accelerator)
    usd_per_vcpu_hour: float = 0.0 # CPU: per-core component
    usd_per_gb_hour: float = 0.0   # CPU: per-GB memory component
    tee_mode: Optional[str] = None # overheads.PROFILES key when TEE-enabled
    step_overhead_s: float = 0.0   # per-step floor (kernel launch/framework)
    bw_derate: float = 1.0         # achieved/peak decode bandwidth (measured
                                   # serving stacks run well below HBM roofline)
    notes: str = ""


SKUS: Dict[str, HardwareSKU] = {
    # Emerald Rapids with AMX (paper's EMR2, per-core GCP spot pricing model)
    "emr-amx": HardwareSKU(
        "emr-amx", "cpu",
        peak_flops=4.1e12,        # ~64 GFLOP/s/core bf16 AMX x 64 cores
        mem_bw=307e9, mem_bytes=512e9,
        usd_per_hour=0.0, usd_per_vcpu_hour=0.011, usd_per_gb_hour=0.0015,
        notes="AMX bf16; paper Fig 12 pricing shape"),
    "emr-amx-tdx": HardwareSKU(
        "emr-amx-tdx", "cpu",
        peak_flops=4.1e12, mem_bw=307e9, mem_bytes=512e9,
        usd_per_hour=0.0, usd_per_vcpu_hour=0.011, usd_per_gb_hour=0.0015,
        tee_mode="tdx", notes="same SKU, TDX enabled"),
    # Sapphire Rapids alternative (paper: ~2x cheaper, up to 40% slower)
    "spr-amx": HardwareSKU(
        "spr-amx", "cpu",
        peak_flops=2.6e12, mem_bw=250e9, mem_bytes=512e9,
        usd_per_hour=0.0, usd_per_vcpu_hour=0.006, usd_per_gb_hour=0.0009),
    # H100 NVL (the paper's ~$30k card; Azure NCCads rates)
    "h100": HardwareSKU(
        "h100", "gpu", peak_flops=990e12, mem_bw=3.9e12, mem_bytes=94e9,
        usd_per_hour=6.98, step_overhead_s=1.0e-3, bw_derate=0.30,
        notes="launch+framework floor per decode step"),
    "h100-cc": HardwareSKU(
        "h100-cc", "gpu", peak_flops=990e12, mem_bw=3.9e12, mem_bytes=94e9,
        usd_per_hour=6.98, tee_mode="cgpu", step_overhead_s=1.0e-3, bw_derate=0.30),
    # TPU v5e (our target platform; forward-looking confidential variant)
    "v5e": HardwareSKU(
        "v5e", "tpu", peak_flops=197e12, mem_bw=819e9, mem_bytes=16e9,
        usd_per_hour=1.20, step_overhead_s=3e-4, bw_derate=0.45),
    "v5e-cc": HardwareSKU(
        "v5e-cc", "tpu", peak_flops=197e12, mem_bw=819e9, mem_bytes=16e9,
        usd_per_hour=1.20, tee_mode="tpu_cc", step_overhead_s=3e-4, bw_derate=0.45),
}


def cpu_hourly_cost(sku: HardwareSKU, vcpus: int, mem_gb: float) -> float:
    return sku.usd_per_hour + vcpus * sku.usd_per_vcpu_hour + mem_gb * sku.usd_per_gb_hour
