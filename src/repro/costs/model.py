"""$/Mtoken cost model — reproduces the paper's Figs 12-13 crossover analysis
and extends it to TPU v5e.

Mechanics (paper §V-D2):
  * a workload = (model params, batch, in/out tokens, dtype bytes);
  * per-step time from the two-term roofline of the SKU (compute vs weight
    streaming), plus the TEE overhead model when the SKU is TEE-enabled;
  * CPU SKUs scale compute with vCPU count until memory-bound (Fig 12's
    32-core plateau); cost = hourly price / tokens-per-hour.

Validated against the paper's qualitative claims:
  * CPU TEE cost advantage at small batch fades and crosses over around
    batch ~128 (Fig 12);
  * doubling input size erodes CPU advantage faster than batch (Fig 13,
    quadratic attention);
  * throughput plateaus at ~32 cores (memory-bound; Insight: resource eff.).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import overheads
from repro.costs.pricing import SKUS, HardwareSKU, cpu_hourly_cost


@dataclasses.dataclass(frozen=True)
class Workload:
    n_params: float
    batch: int
    in_tokens: int
    out_tokens: int
    bytes_per_param: float = 2.0   # bf16
    d_model: int = 4096
    n_layers: int = 32

    @property
    def kv_bytes_per_token(self) -> float:
        return 2 * self.n_layers * self.d_model * self.bytes_per_param


def step_terms(w: Workload, sku: HardwareSKU, vcpus: Optional[int] = None
               ) -> overheads.RooflineTerms:
    """Roofline terms for ONE decode step over the whole batch."""
    flops = 2 * w.n_params * w.batch
    # attention read: KV cache of current length (use in_tokens as proxy)
    attn_bytes = w.batch * w.in_tokens * w.kv_bytes_per_token
    weight_bytes = w.n_params * w.bytes_per_param  # streamed once per step
    peak = sku.peak_flops
    if sku.kind == "cpu" and vcpus is not None:
        peak = sku.peak_flops * min(vcpus, 64) / 64.0
    compute_s = flops / peak
    memory_s = (weight_bytes + attn_bytes) / (sku.mem_bw * sku.bw_derate)
    return overheads.RooflineTerms(compute_s=compute_s, memory_s=memory_s)


def tokens_per_second(w: Workload, sku: HardwareSKU,
                      vcpus: Optional[int] = None) -> float:
    terms = step_terms(w, sku, vcpus)
    step_s = max(terms.compute_s, terms.memory_s) + sku.step_overhead_s
    if sku.tee_mode:
        ov = overheads.predict(terms, sku.tee_mode).overhead
        step_s *= (1 + ov)
    return w.batch / step_s


def usd_per_mtok(w: Workload, sku_name: str, vcpus: int = 32,
                 mem_gb: float = 128.0) -> float:
    sku = SKUS[sku_name]
    tps = tokens_per_second(w, sku, vcpus if sku.kind == "cpu" else None)
    hourly = (cpu_hourly_cost(sku, vcpus, mem_gb) if sku.kind == "cpu"
              else sku.usd_per_hour)
    return hourly / (tps * 3600.0) * 1e6


def vcpu_sweep(w: Workload, sku_name: str, vcpu_counts: List[int],
               mem_gb: float = 128.0) -> Dict[int, Dict[str, float]]:
    """Fig 12 rows: throughput + $/Mtok across machine sizes."""
    out = {}
    for v in vcpu_counts:
        sku = SKUS[sku_name]
        tps = tokens_per_second(w, sku, v)
        out[v] = {"tokens_per_s": tps,
                  "usd_per_mtok": usd_per_mtok(w, sku_name, v, mem_gb)}
    return out


def best_cpu_cost(w: Workload, cpu_sku: str,
                  vcpu_grid=(4, 8, 16, 32, 64), mem_gb: float = 128.0) -> float:
    """The paper compares against the best CPU machine size per workload
    (Fig 12 picks the cost-optimal vCPU count)."""
    return min(usd_per_mtok(w, cpu_sku, v, mem_gb) for v in vcpu_grid)


def crossover_batch(w_base: Workload, cpu_sku: str, gpu_sku: str,
                    batches: List[int]) -> Optional[int]:
    """Smallest batch where the GPU's $/Mtok <= the best CPU config's
    (Fig 12's orange line)."""
    for b in batches:
        w = dataclasses.replace(w_base, batch=b)
        if usd_per_mtok(w, gpu_sku) <= best_cpu_cost(w, cpu_sku):
            return b
    return None
