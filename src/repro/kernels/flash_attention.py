"""Causal flash attention Pallas kernel (blocked online softmax).

Prefill is the compute hot-spot of the serving path (paper Fig 7: self-attn
dominates block time). This kernel tiles Q and KV into VMEM blocks and keeps
the running (max, sum, acc) online-softmax state in VMEM scratch across the
KV grid dimension, so the S x S score matrix is never materialized in HBM —
the standard memory-roofline win, re-tiled for (8,128)-lane VMEM.

Layout: q/k/v are [heads_batched, seq, head_dim] (fold batch*heads outside).
Grid: (bh, q_blocks, kv_blocks), kv innermost sequential. Causal blocks where
kv_start > q_end are skipped via ``pl.when`` (their tiles still stream, but
no compute is issued — block-level masking handles the diagonal).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, kv_steps: int, bq: int, bkv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks entirely above the diagonal
    @pl.when(ki * bkv <= qi * bq + bq - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bkv, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

        m_prev = m_ref[...]                        # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                     # [bq, bkv]
        alpha = jnp.exp(m_prev - m_new)            # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)           # [bkv, d]
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Causal attention. q/k/v: [bh, s, d] with s % bq == s % bkv == 0."""
    bh, s, d = q.shape
    assert k.shape == v.shape == (bh, s, d)
    assert s % bq == 0 and s % bkv == 0, (s, bq, bkv)
    scale = 1.0 / np.sqrt(d)
    kv_steps = s // bkv
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, kv_steps=kv_steps,
                          bq=bq, bkv=bkv),
        grid=(bh, s // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
