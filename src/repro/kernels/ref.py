"""Pure-jnp oracles for every Pallas kernel in this package.

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle; the oracles
themselves are validated against external ground truth where it exists
(ChaCha20: RFC 8439 test vectors).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.chacha20 import chacha_block_words


# ---------------------------------------------------------------------------
# chacha20
# ---------------------------------------------------------------------------

def chacha20_keystream_ref(key_words: jax.Array, nonce_words: jax.Array,
                           n_blocks: int, counter_base: int = 0) -> jax.Array:
    """Keystream as uint32 [16, n_blocks] (word w of block b at [w, b])."""
    counters = jnp.arange(counter_base, counter_base + n_blocks, dtype=jnp.uint32)
    words = chacha_block_words([key_words[i] for i in range(8)],
                               [nonce_words[i] for i in range(3)], counters)
    return jnp.stack(words, axis=0)


def chacha20_xor_ref(key_words: jax.Array, nonce_words: jax.Array,
                     data: jax.Array, counter_base: int = 0) -> jax.Array:
    """Oracle for chacha20_xor_blocked: data uint32 [16, N]."""
    ks = chacha20_keystream_ref(key_words, nonce_words, data.shape[1], counter_base)
    return data ^ ks


def chacha20_keystream_bytes_ref(key: bytes, nonce: bytes, n_bytes: int,
                                 counter_base: int = 0) -> bytes:
    """Byte-level RFC 8439 keystream (little-endian serialization), for
    checking against published test vectors."""
    kw = jnp.asarray(np.frombuffer(key, np.uint32))
    nw = jnp.asarray(np.frombuffer(nonce, np.uint32))
    nblocks = (n_bytes + 63) // 64
    ks = np.asarray(chacha20_keystream_ref(kw, nw, nblocks, counter_base))
    # [16, N] -> per-block LE bytes
    out = ks.T.astype("<u4").tobytes()
    return out[:n_bytes]


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

def qmatmul_ref(x_q: jax.Array, w_q: jax.Array, scale: jax.Array,
                out_dtype=jnp.bfloat16) -> jax.Array:
    """int8 [M,K] x int8 [K,N] * scale [1,N] -> out_dtype [M,N]."""
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * scale).astype(out_dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal MHA. q/k/v: [bh, s, d]."""
    bh, s, d = q.shape
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32)).astype(q.dtype)
