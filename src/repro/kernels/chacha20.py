"""ChaCha20 keystream + XOR Pallas kernel — on-device unseal of sealed tensors.

This is the TPU-native analogue of TDX/SGX inline memory encryption
(DESIGN.md §2): sealed weights/KV pages live in HBM as ciphertext and are
decrypted on the way into compute. ChaCha20 (RFC 8439) is integer-only
(add/xor/rotl on uint32) and vectorizes perfectly on the VPU: each lane
computes an independent 64-byte block.

Data layout: a sealed buffer is a uint32 array of shape [16, N] — word w of
block b at [w, b] — so the lane dimension is the block counter and the kernel
is a pure elementwise pipeline with (16, BLOCKS)-shaped VMEM tiles. The host
packs bytes into this layout once at seal time (core/sealing.py), i.e.
ciphertext is stored on disk in the kernel-friendly layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128-lane multiple; 1024 blocks/tile = 64 KiB keystream per tile, well under
# VMEM while giving the VPU long vectors.
BLOCKS_PER_TILE = 1024

CONSTANTS = (0x61707865, 0x3320646E, 0x79622D32, 0x6B206574)


def _rotl(x: jax.Array, n: int) -> jax.Array:
    return (x << jnp.uint32(n)) | (x >> jnp.uint32(32 - n))


def _quarter(state, a, b, c, d):
    sa, sb, sc, sd = state[a], state[b], state[c], state[d]
    sa = sa + sb
    sd = _rotl(sd ^ sa, 16)
    sc = sc + sd
    sb = _rotl(sb ^ sc, 12)
    sa = sa + sb
    sd = _rotl(sd ^ sa, 8)
    sc = sc + sd
    sb = _rotl(sb ^ sc, 7)
    state[a], state[b], state[c], state[d] = sa, sb, sc, sd


def chacha_block_words(key_words, nonce_words, counters):
    """Vectorized ChaCha20 block fn. counters: uint32 array (any shape).

    Returns a list of 16 uint32 arrays shaped like ``counters``.
    Shared by the Pallas kernel body and the jnp reference (ref.py), so the
    round structure has a single source of truth; the *kernel* is the tiled
    pallas_call wrapping below.
    """
    shape = counters.shape
    full = lambda v: jnp.full(shape, v, jnp.uint32)
    init = ([full(c) for c in CONSTANTS]
            + [jnp.broadcast_to(w.astype(jnp.uint32), shape) for w in key_words]
            + [counters.astype(jnp.uint32)]
            + [jnp.broadcast_to(w.astype(jnp.uint32), shape) for w in nonce_words])
    state = list(init)
    for _ in range(10):  # 10 double rounds = 20 rounds
        _quarter(state, 0, 4, 8, 12)
        _quarter(state, 1, 5, 9, 13)
        _quarter(state, 2, 6, 10, 14)
        _quarter(state, 3, 7, 11, 15)
        _quarter(state, 0, 5, 10, 15)
        _quarter(state, 1, 6, 11, 12)
        _quarter(state, 2, 7, 8, 13)
        _quarter(state, 3, 4, 9, 14)
    return [s + i for s, i in zip(state, init)]


def _xor_kernel(key_ref, nonce_ref, data_ref, out_ref, *, counter_base: int):
    """One tile: data (16, BLOCKS) uint32 XOR keystream for counters
    [base + pid*BLOCKS, ...)."""
    pid = pl.program_id(0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, data_ref.shape[1]), 1)
    counters = (jnp.uint32(counter_base)
                + pid.astype(jnp.uint32) * jnp.uint32(data_ref.shape[1]) + lane)
    key_words = [key_ref[0, i] for i in range(8)]
    nonce_words = [nonce_ref[0, i] for i in range(3)]
    words = chacha_block_words(key_words, nonce_words, counters)
    ks = jnp.concatenate(words, axis=0)  # (16, BLOCKS)
    out_ref[...] = data_ref[...] ^ ks


@functools.partial(jax.jit, static_argnames=("counter_base", "interpret"))
def chacha20_xor_blocked(key: jax.Array, nonce: jax.Array, data: jax.Array,
                         counter_base: int = 0, interpret: bool = True) -> jax.Array:
    """XOR ``data`` (uint32 [16, N], N multiple of BLOCKS_PER_TILE) with the
    ChaCha20 keystream. Involution: applying twice returns the input."""
    assert data.dtype == jnp.uint32 and data.shape[0] == 16, data.shape
    n = data.shape[1]
    assert n % BLOCKS_PER_TILE == 0, n
    grid = (n // BLOCKS_PER_TILE,)
    return pl.pallas_call(
        functools.partial(_xor_kernel, counter_base=counter_base),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),    # key words (replicated)
            pl.BlockSpec((1, 3), lambda i: (0, 0)),    # nonce words
            pl.BlockSpec((16, BLOCKS_PER_TILE), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((16, BLOCKS_PER_TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct(data.shape, jnp.uint32),
        interpret=interpret,
    )(key.reshape(1, 8), nonce.reshape(1, 3), data)
