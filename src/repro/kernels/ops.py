"""jit'd public wrappers around the Pallas kernels.

Handles padding/packing so callers see natural shapes:
  * seal_u32 / unseal_u32 — arbitrary tensors <-> blocked ciphertext layout
  * qmm — bf16 activations x QTensor weights with auto-padding to tiles
  * mha_flash — [b, s, h, d] attention with GQA head broadcast
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.chacha20 import BLOCKS_PER_TILE, chacha20_xor_blocked
from repro.kernels.flash_attention import flash_attention
from repro.kernels.qmatmul import qmatmul
from repro.quant.quantize import QTensor

# interpret=True everywhere in this container (CPU). On TPU deploys this flag
# flips to False via the environment; the call sites are unchanged.
INTERPRET = True


# ---------------------------------------------------------------------------
# sealing: pack arbitrary arrays into the [16, N] blocked u32 layout
# ---------------------------------------------------------------------------

def pack_u32(raw: np.ndarray) -> Tuple[jax.Array, int]:
    """uint8 bytes -> (uint32 [16, N] blocked layout, original byte length)."""
    n_bytes = raw.size
    block_bytes = 64 * BLOCKS_PER_TILE
    padded = n_bytes + (-n_bytes) % block_bytes
    buf = np.zeros(padded, np.uint8)
    buf[:n_bytes] = raw
    words = buf.view("<u4").reshape(-1, 16).T  # [16, N]
    return jnp.asarray(np.ascontiguousarray(words)), n_bytes


def unpack_u32(words: jax.Array, n_bytes: int) -> np.ndarray:
    """Inverse of pack_u32 -> uint8[n_bytes]."""
    out = np.asarray(words).T.astype("<u4").tobytes()
    return np.frombuffer(out[:n_bytes], np.uint8).copy()


def seal_u32(key_words: jax.Array, nonce_words: jax.Array,
             blocked: jax.Array, counter_base: int = 0) -> jax.Array:
    """XOR blocked data with the keystream (seal == unseal: involution)."""
    return chacha20_xor_blocked(key_words, nonce_words, blocked,
                                counter_base=counter_base, interpret=INTERPRET)


unseal_u32 = seal_u32  # stream-cipher involution


# ---------------------------------------------------------------------------
# quantized matmul
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def qmm(x: jax.Array, w: QTensor, *, bm: int = 128, bn: int = 128,
        bk: int = 128) -> jax.Array:
    """bf16 [M, K] x QTensor([K, N]) -> bf16 [M, N] via the int8 MXU kernel.

    Dynamically quantizes activations per-tensor (AMX dataflow), folds the
    activation scale into the per-channel weight scale, pads to tile
    multiples, and un-pads the result.
    """
    m, kdim = x.shape
    k2, n = w.values.shape
    assert kdim == k2
    xf = x.astype(jnp.float32)
    xmax = jnp.max(jnp.abs(xf))
    xscale = jnp.where(xmax > 0, xmax / 127.0, 1.0)
    xq = jnp.clip(jnp.round(xf / xscale), -127, 127).astype(jnp.int8)

    xq = _pad_to(_pad_to(xq, 0, bm), 1, bk)
    wq = _pad_to(_pad_to(w.values, 0, bk), 1, bn)
    scale = _pad_to(w.scale.reshape(1, n) * xscale, 1, bn)
    out = qmatmul(xq, wq, scale, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
    return out[:m, :n].astype(x.dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def mha_flash(q: jax.Array, k: jax.Array, v: jax.Array, *, bq: int = 128,
              bkv: int = 128) -> jax.Array:
    """Causal attention, [b, s, h, d] layout with GQA broadcast."""
    b, s, h, d = q.shape
    hk = k.shape[2]
    if hk != h:
        rep = h // hk
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    bq_ = min(bq, s)
    bkv_ = min(bkv, s)
    # non-block-multiple sequence: zero-pad to a common block multiple. The
    # kernel's causal mask sends every padded kv position (k_pos >= s >
    # q_pos for all real rows) to NEG_INF, and padded query rows are
    # sliced away below, so padding is invisible to the result.
    s_pad = s + (-s) % int(np.lcm(bq_, bkv_))
    if s_pad != s:
        widths = ((0, 0), (0, s_pad - s), (0, 0))
        qf, kf, vf = (jnp.pad(a, widths) for a in (qf, kf, vf))
    out = flash_attention(qf, kf, vf, bq=bq_, bkv=bkv_, interpret=INTERPRET)
    return out[:, :s].reshape(b, h, s, d).transpose(0, 2, 1, 3)
