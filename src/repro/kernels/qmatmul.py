"""int8 x int8 -> int32 tiled matmul Pallas kernel (the AMX -> MXU adaptation).

The paper's Insight 3/8: AMX int8/bf16 tiles double CPU inference speed and
shrink relative TEE overhead. The TPU analogue is the MXU's native int8 path:
we tile (M, K) x (K, N) into 128-aligned VMEM blocks, accumulate in an int32
VMEM scratch across the K grid dimension, and apply the (folded
activation x per-output-channel weight) scale on the final K step so the
output leaves VMEM once, in bf16.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics — sequential
accumulation); M, N parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qmm_kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _emit():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * scale_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def qmatmul(x_q: jax.Array, w_q: jax.Array, scale: jax.Array, *,
            bm: int = 128, bn: int = 128, bk: int = 128,
            out_dtype=jnp.bfloat16, interpret: bool = True) -> jax.Array:
    """x_q: int8 [M, K]; w_q: int8 [K, N]; scale: f32 [1, N]
    (activation scale already folded in). Returns [M, N] ``out_dtype``.

    M, K, N must be multiples of the block sizes (ops.py pads).
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2 and scale.shape == (1, n)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_qmm_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, bn), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, scale)
