"""Paged decode-attention Pallas kernel with optional fused in-kernel unseal.

The gather decode path (runtime/paged.py) rematerializes each sequence's
full KV per step: ``jnp.take`` over the ``[slots, max_pages]`` page table
builds the dense view ``model.decode_step`` expects, so per-step HBM
traffic grows with context length even though decode reads each KV element
exactly once. This kernel removes the gather: the page table rides in as a
scalar-prefetch operand and the BlockSpec index_map dereferences it
directly, so KV pages stream from the ``[num_pages+1, page_size, ...]``
pool into VMEM one page per grid step — vLLM-PagedAttention shape, re-tiled
on flash_attention.py's online-softmax VMEM scratch pattern.

``paged_attention_unseal`` goes one step further than a plaintext pool: a
per-page crypt sidecar (nonce words + live flag) lets sealed pages stay
*ciphertext-resident* in HBM after a restore. The kernel regenerates the
ChaCha20 keystream (chacha20.py's block function, counter_base derived from
the layer ordinal exactly as core/sealing.py laid the blocks out) and XORs
the page on the way into the attention dot — the TPU-native analogue of TDX
inline memory encryption. Restored pages then never round-trip plaintext KV
through HBM; MAC verification still happens on the host *before* the
ciphertext is admitted to the pool (see sealing.verify_mac).

Layout: one layer per call — q ``[slots, heads, head_dim]`` (the single
post-RoPE decode token per slot), pools ``[num_pages+1, page_size,
kv_heads, head_dim]`` (page 0 is the null scratch page), table ``[slots,
max_pages]`` int32, valid ``[slots]`` int32 (= pos + 1; the slot attends to
positions < valid). Grid (slots, max_pages), pages innermost sequential.
Pages wholly past ``valid`` skip compute via ``pl.when``; the in-page tail
is masked to NEG_INF like the causal diagonal in flash_attention.

Interpret-container stand-in (``emulate``): Pallas interpret mode copies
every operand block on every grid step, so a (slots, pages) grid over a
pooled operand costs O(grid x pool bytes) per call — quadratic in context
on the CPU containers this repo's tests and benches run in, drowning the
very gather the kernel exists to remove. ``emulate=True`` (the default
whenever ``interpret=True``) therefore runs the *same* page walk — same
table dereference, same ``_attend_page_math`` update per page, same
masking — as a ``lax.fori_loop`` over pages under ``vmap`` over slots,
touching each mapped page exactly once. Tests pin the emulation bit-exact
against the Pallas kernel's interpret output; compiled TPU runs
(``interpret=False``) always take the real ``pallas_call``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.chacha20 import chacha_block_words

NEG_INF = -1e30

# pool dtypes the in-kernel XOR path supports (bitcast to a lane-word view);
# anything else restores through the host-decrypt path instead.
FUSED_DTYPES = (jnp.float32, jnp.bfloat16)


def supports_fused_unseal(dtype, page_bytes: int) -> bool:
    """True when a page of this dtype can be decrypted in-kernel: the page
    must cover whole ChaCha20 blocks (64 B) and bitcast to uint words."""
    return page_bytes % 64 == 0 and jnp.dtype(dtype) in (
        jnp.dtype(d) for d in FUSED_DTYPES)


def _page_keystream(key_ref, nonce, layer, bpp: int) -> jax.Array:
    """Linear uint32 keystream for one page: ``bpp`` ChaCha20 blocks at
    counter_base = layer * bpp (core/sealing.py packs a page's L layers
    contiguously, layer l at blocks [l*bpp, (l+1)*bpp)). Linear word i is
    word i%16 of counter block i//16 — the same permutation ops.pack_u32's
    ``.T.reshape(-1)`` applies when serializing blocked ciphertext."""
    counters = (layer.astype(jnp.uint32) * jnp.uint32(bpp)
                + jax.lax.broadcasted_iota(jnp.uint32, (1, bpp), 1))
    key_words = [key_ref[i] for i in range(8)]
    words = chacha_block_words(key_words, list(nonce), counters)
    return jnp.stack(words, axis=-1).reshape(-1)        # [bpp * 16]


def _unseal_tile(tile: jax.Array, crypt_row, key_ref, layer,
                 bpp: int) -> jax.Array:
    """XOR a KV page tile with its keystream iff its crypt flag is live.
    The flag-dead branch must be bit-exact identity (plaintext pages share
    the same code path), hence where() on the bitcast words."""
    live = crypt_row[3] > 0
    ks32 = _page_keystream(key_ref, (crypt_row[0], crypt_row[1],
                                     crypt_row[2]), layer, bpp)
    if tile.dtype == jnp.dtype(jnp.float32):
        bits = jax.lax.bitcast_convert_type(tile, jnp.uint32).reshape(-1)
        plain = jnp.where(live, bits ^ ks32, bits)
        return jax.lax.bitcast_convert_type(
            plain.reshape(tile.shape), jnp.float32)
    # bfloat16: element e occupies bytes [2e, 2e+2) little-endian, so the
    # keystream word for elements (2w, 2w+1) splits into (low, high) halves.
    lo = (ks32 & jnp.uint32(0xFFFF)).astype(jnp.uint16)
    hi = (ks32 >> jnp.uint32(16)).astype(jnp.uint16)
    ks16 = jnp.stack([lo, hi], axis=-1).reshape(-1)
    bits = jax.lax.bitcast_convert_type(tile, jnp.uint16).reshape(-1)
    plain = jnp.where(live, bits ^ ks16, bits)
    return jax.lax.bitcast_convert_type(
        plain.reshape(tile.shape), jnp.bfloat16)


def _attend_page_math(q32, k, v, j, valid, m_prev, l_prev, acc_prev, *,
                      scale: float, page_size: int):
    """One online-softmax update over one KV page (GQA batched over kv
    heads), as a pure function — shared verbatim by the Pallas kernel body
    and the interpret-container jnp emulation so the two stay bit-aligned.
    q32 [h, hd] f32; k/v [page_size, hk, hd] (any dtype)."""
    h, hd = q32.shape
    hk = k.shape[1]
    g = h // hk
    qg = q32.reshape(hk, g, hd)
    kt = k.astype(jnp.float32).transpose(1, 0, 2)       # [hk, ps, hd]
    s = jax.lax.dot_general(qg, kt, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    k_pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (hk, g, page_size), 2)
    s = jnp.where(k_pos < valid, s, NEG_INF).reshape(h, page_size)

    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                               # [h, ps]
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    vt = v.astype(jnp.float32).transpose(1, 0, 2)        # [hk, ps, hd]
    pv = jax.lax.dot_general(p.reshape(hk, g, page_size), vt,
                             (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    return m_new, l_new, acc_prev * alpha + pv.reshape(h, hd)


def _attend_page(q32, k, v, j, valid, m_ref, l_ref, acc_ref, *,
                 scale: float, page_size: int):
    m_ref[...], l_ref[...], acc_ref[...] = _attend_page_math(
        q32, k, v, j, valid, m_ref[...], l_ref[...], acc_ref[...],
        scale=scale, page_size=page_size)


def _paged_kernel(table_ref, valid_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, page_size: int,
                  pages: int):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[i]

    @pl.when(j * page_size < valid)
    def _compute():
        _attend_page(q_ref[0].astype(jnp.float32), k_ref[0], v_ref[0],
                     j, valid, m_ref, l_ref, acc_ref,
                     scale=scale, page_size=page_size)

    @pl.when(j == pages - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_unseal_kernel(table_ref, valid_ref, layer_ref, key_ref,
                         q_ref, k_ref, v_ref, kc_ref, vc_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float,
                         page_size: int, pages: int, bpp: int):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[i]
    layer = layer_ref[0]

    @pl.when(j * page_size < valid)
    def _compute():
        k = _unseal_tile(k_ref[0], kc_ref[0], key_ref, layer, bpp)
        v = _unseal_tile(v_ref[0], vc_ref[0], key_ref, layer, bpp)
        _attend_page(q_ref[0].astype(jnp.float32), k, v, j, valid,
                     m_ref, l_ref, acc_ref, scale=scale,
                     page_size=page_size)

    @pl.when(j == pages - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _emulated_walk(table, valid, q, k_pool, v_pool, unseal=None):
    """The kernel's page walk as plain jnp: vmap over slots, fori_loop over
    table columns, one dynamic page load per step, ``_attend_page_math``
    verbatim. Pages past ``valid`` still execute (loop bounds are static)
    but their carry update is where()-discarded — the same values the
    Pallas kernel's ``pl.when`` produces, at O(mapped pages) cost."""
    _, h, hd = q.shape
    _, ps, _, _ = k_pool.shape
    pages = table.shape[1]
    scale = 1.0 / np.sqrt(hd)

    def one_slot(qi, row, vi):
        q32 = qi.astype(jnp.float32)

        def body(j, carry):
            m, l, acc = carry
            phys = row[j]
            k, v = k_pool[phys], v_pool[phys]
            if unseal is not None:
                k, v = unseal(phys, k, v)
            m2, l2, a2 = _attend_page_math(q32, k, v, j, vi, m, l, acc,
                                           scale=scale, page_size=ps)
            live = j * ps < vi
            return (jnp.where(live, m2, m), jnp.where(live, l2, l),
                    jnp.where(live, a2, acc))

        init = (jnp.full((h, 1), NEG_INF, jnp.float32),
                jnp.zeros((h, 1), jnp.float32),
                jnp.zeros((h, hd), jnp.float32))
        m, l, acc = jax.lax.fori_loop(0, pages, body, init)
        return (acc / jnp.maximum(l, 1e-30)).astype(qi.dtype)

    return jax.vmap(one_slot)(q, table, valid)


def _specs(h, hd, ps, hk, n_prefetch):
    """Common BlockSpecs: q/out by slot, pools dereferenced through the
    prefetched page table (index_map args: grid indices then prefetch refs)."""
    q_spec = pl.BlockSpec((1, h, hd), lambda i, j, *refs: (i, 0, 0))
    pool_spec = pl.BlockSpec(
        (1, ps, hk, hd), lambda i, j, *refs: (refs[0][i, j], 0, 0, 0))
    return q_spec, pool_spec


@functools.partial(jax.jit, static_argnames=("interpret", "emulate"))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    table: jax.Array, valid: jax.Array, *,
                    interpret: bool = True,
                    emulate: bool | None = None) -> jax.Array:
    """Decode attention over a paged KV pool, no dense gather.

    q ``[slots, heads, head_dim]``; pools ``[num_pages+1, page_size,
    kv_heads, head_dim]``; table ``[slots, max_pages]`` int32 physical page
    ids (0 = null page); valid ``[slots]`` int32 attended prefix length.
    Returns ``[slots, heads, head_dim]`` in q's dtype. Rows whose table
    maps nowhere (idle slots) produce garbage the engine discards.

    ``emulate`` (default: follow ``interpret``) swaps the ``pallas_call``
    for the bit-aligned jnp page walk — see the module docstring. Pass
    ``emulate=False`` with ``interpret=True`` to force the Pallas
    interpreter (tests pin the two paths against each other).
    """
    b, h, hd = q.shape
    _, ps, hk, _ = k_pool.shape
    pages = table.shape[1]
    assert h % hk == 0, (h, hk)
    if emulate is None:
        emulate = interpret
    if emulate:
        return _emulated_walk(table.astype(jnp.int32),
                              valid.astype(jnp.int32), q, k_pool, v_pool)
    scale = 1.0 / np.sqrt(hd)
    q_spec, pool_spec = _specs(h, hd, ps, hk, 2)
    return pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, page_size=ps,
                          pages=pages),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, pages),
            in_specs=[q_spec, pool_spec, pool_spec],
            out_specs=pl.BlockSpec((1, h, hd), lambda i, j, *refs: (i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((h, 1), jnp.float32),    # running max
                pltpu.VMEM((h, 1), jnp.float32),    # running sum
                pltpu.VMEM((h, hd), jnp.float32),   # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), valid.astype(jnp.int32), q, k_pool, v_pool)


@functools.partial(jax.jit,
                   static_argnames=("blocks_per_page", "interpret",
                                    "emulate"))
def paged_attention_unseal(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, table: jax.Array,
                           valid: jax.Array, layer: jax.Array,
                           key_words: jax.Array, k_crypt: jax.Array,
                           v_crypt: jax.Array, *, blocks_per_page: int,
                           interpret: bool = True,
                           emulate: bool | None = None) -> jax.Array:
    """paged_attention over a pool whose pages may be ciphertext-resident.

    ``k_crypt``/``v_crypt`` ``[num_pages+1, 4]`` uint32 sidecars: words 0-2
    are the page blob's ChaCha20 nonce (core/sealing.py's
    sha256(key_id|name)[:12]), word 3 is the live flag (0 = plaintext page,
    XOR skipped bit-exactly). ``layer`` is the layer ordinal (int32 scalar
    or shape-[1]); counter_base = layer * blocks_per_page matches the
    sealed blob's contiguous [L, page] packing. ``key_words`` is the uint32
    [8] sealing key (SealingKey.key_words).
    """
    b, h, hd = q.shape
    _, ps, hk, _ = k_pool.shape
    pages = table.shape[1]
    assert h % hk == 0, (h, hk)
    page_bytes = ps * hk * hd * jnp.dtype(k_pool.dtype).itemsize
    assert page_bytes == blocks_per_page * 64, (page_bytes, blocks_per_page)
    assert supports_fused_unseal(k_pool.dtype, page_bytes), k_pool.dtype
    if emulate is None:
        emulate = interpret
    if emulate:
        key = key_words.astype(jnp.uint32).reshape(8)
        lay = jnp.asarray(layer, jnp.int32).reshape(())
        kc = k_crypt.astype(jnp.uint32)
        vc = v_crypt.astype(jnp.uint32)

        def unseal(phys, k, v):
            return (_unseal_tile(k, kc[phys], key, lay, blocks_per_page),
                    _unseal_tile(v, vc[phys], key, lay, blocks_per_page))

        return _emulated_walk(table.astype(jnp.int32),
                              valid.astype(jnp.int32), q, k_pool, v_pool,
                              unseal=unseal)
    scale = 1.0 / np.sqrt(hd)
    q_spec, pool_spec = _specs(h, hd, ps, hk, 4)
    crypt_spec = pl.BlockSpec(
        (1, 4), lambda i, j, *refs: (refs[0][i, j], 0))
    return pl.pallas_call(
        functools.partial(_paged_unseal_kernel, scale=scale, page_size=ps,
                          pages=pages, bpp=blocks_per_page),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(b, pages),
            in_specs=[q_spec, pool_spec, pool_spec, crypt_spec, crypt_spec],
            out_specs=pl.BlockSpec((1, h, hd), lambda i, j, *refs: (i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), valid.astype(jnp.int32),
      jnp.asarray(layer, jnp.int32).reshape(1),
      key_words.astype(jnp.uint32).reshape(8),
      q, k_pool, v_pool, k_crypt, v_crypt)
