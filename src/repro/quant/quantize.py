"""Per-channel int8 absmax quantization — the AMX int8 path mapped to the MXU.

The paper (Insights 3/8) shows AMX's native int8/bf16 tiles both speed up
inference and shrink *relative* TEE overhead by raising arithmetic intensity.
We reproduce the mechanism: weights quantize to int8 with per-output-channel
scales, matmuls run int8 x int8 -> int32 on the MXU (kernels/qmatmul.py), and
activations stay bf16 (weight-only quantization, the deployment-relevant mode
for LLM serving).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    """int8 values + f32 per-channel scale over the LAST axis."""
    values: jax.Array   # int8
    scale: jax.Array    # f32, shape = values.shape[:-2] + (1, values.shape[-1])

    @property
    def shape(self):
        return self.values.shape

    @property
    def dtype(self):
        return self.values.dtype


def quantize_int8(w: jax.Array, axis: int = -2) -> QTensor:
    """Quantize along ``axis`` (the contraction axis), per-channel on the rest.

    Default axis=-2 matches (in_features, out_features) weight layout: one
    scale per output channel.
    """
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale)


def dequantize(q: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    return (q.values.astype(jnp.float32) * q.scale).astype(dtype)


def qmatmul_ref(x: jax.Array, q: QTensor) -> jax.Array:
    """bf16 activations x int8 weights -> bf16. Pure-jnp oracle.

    Dynamic per-tensor activation quantization to int8, int32 accumulate,
    rescale — the AMX int8 GEMM dataflow.
    """
    xf = x.astype(jnp.float32)
    xmax = jnp.max(jnp.abs(xf))
    xscale = jnp.where(xmax > 0, xmax / 127.0, 1.0)
    xq = jnp.clip(jnp.round(xf / xscale), -127, 127).astype(jnp.int8)
    acc = jax.lax.dot_general(xq, q.values, (((xq.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * xscale * q.scale.reshape(1, -1)).astype(x.dtype)


def quantize_params(params: Any, min_size: int = 1 << 12) -> Any:
    """Quantize every >=2D float leaf of a param tree to a QTensor.

    Small tensors (norms, biases) stay in bf16 — matching IPEX int8 recipes,
    which keep normalization layers in higher precision.
    """
    def q(leaf):
        if (hasattr(leaf, "ndim") and leaf.ndim >= 2 and leaf.size >= min_size
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return quantize_int8(leaf)
        return leaf
    return jax.tree.map(q, params)


def quantized_bytes(params: Any) -> int:
    """Total bytes of a (possibly quantized) param tree."""
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total
