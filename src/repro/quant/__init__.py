from repro.quant.quantize import (
    QTensor, quantize_int8, dequantize, quantize_params, qmatmul_ref,
    quantized_bytes,
)

__all__ = ["QTensor", "quantize_int8", "dequantize", "quantize_params",
           "qmatmul_ref", "quantized_bytes"]
