"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(dense ffn first 3L,
then MoE 1 shared + 256 routed top-8, expert d_ff=2048), vocab=129280, MLA.
MTP head omitted from serve path (DESIGN.md §8). [arXiv:2412.19437; hf]"""

from repro.configs import base


@base.register("deepseek-v3-671b")
def config() -> base.ModelConfig:
    return base.ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=2048,
        vocab_size=129280,
        moe=base.MoESpec(num_experts=256, top_k=8, d_ff_expert=2048,
                         num_shared_experts=1, gating="sigmoid",
                         first_k_dense=3),
        mla=base.MLASpec(q_lora_rank=1536, kv_lora_rank=512, rope_dim=64,
                         nope_dim=128, v_head_dim=128),
        parallel=base.ParallelConfig(fsdp=True, optimizer_dtype="bfloat16"),
        source="arXiv:2412.19437; hf",
    )
