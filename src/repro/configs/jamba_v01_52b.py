"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba+attn 1:7 interleave, MoE 16e top-2 every other layer.
Sub-quadratic (4/32 attention layers) -> long_500k RUNS. [arXiv:2403.19887; hf]"""

from repro.configs import base


@base.register("jamba-v0.1-52b")
def config() -> base.ModelConfig:
    return base.ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        attn_period=8,   # layer i is attention iff i % 8 == 7 (1:7 ratio)
        moe_period=2,    # MoE FFN every 2nd layer
        moe=base.MoESpec(num_experts=16, top_k=2, d_ff_expert=14336),
        ssm=base.SSMSpec(kind="mamba", d_state=16, d_conv=4, expand=2),
        sub_quadratic=True,
        parallel=base.ParallelConfig(fsdp=True),
        source="arXiv:2403.19887; hf",
    )
