"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536, early-fusion VQ image tokens (frontend stub: image tokens arrive
as ids in the shared vocab), qk-norm. [arXiv:2405.09818; unverified]"""

from repro.configs import base


@base.register("chameleon-34b")
def config() -> base.ModelConfig:
    return base.ModelConfig(
        name="chameleon-34b",
        family="vlm",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        qk_norm=True,
        parallel=base.ParallelConfig(fsdp=True),
        source="arXiv:2405.09818; unverified",
    )
