"""The paper's own models: Llama2 7B / 13B / 70B (Touvron et al. 2023).

These are what the paper actually ran inside TDX/SGX/cGPU; the benchmark
layer measures reduced-scale versions of these, and the dry-run can lower the
full ones like any assigned arch.
"""

from repro.configs import base


@base.register("llama2-7b")
def llama2_7b() -> base.ModelConfig:
    return base.ModelConfig(
        name="llama2-7b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
        d_ff=11008, vocab_size=32000,
        source="arXiv:2307.09288; hf",
    )


@base.register("llama2-13b")
def llama2_13b() -> base.ModelConfig:
    return base.ModelConfig(
        name="llama2-13b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=40,
        d_ff=13824, vocab_size=32000,
        source="arXiv:2307.09288; hf",
    )


@base.register("llama2-70b")
def llama2_70b() -> base.ModelConfig:
    return base.ModelConfig(
        name="llama2-70b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=28672, vocab_size=32000,
        parallel=base.ParallelConfig(fsdp=True),
        source="arXiv:2307.09288; hf",
    )
