"""Config system: model/parallelism/shape dataclasses + registry.

Every assigned architecture registers a :class:`ModelConfig` here. Shapes are
the four assigned (seq_len, global_batch) cells; ``step_kind`` tells the
launcher which program to lower (train_step / prefill / serve_step).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    gating: str = "softmax"
    capacity_factor: float = 1.25
    first_k_dense: int = 0   # deepseek-v3: first 3 layers use dense FFN


@dataclasses.dataclass(frozen=True)
class MLASpec:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    kind: str = "mamba"           # "mamba" | "rwkv6"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # rwkv6
    lora_rank: int = 64           # rwkv6
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the mesh (DP/FSDP/TP/EP/SP knobs)."""
    fsdp: bool = False                 # shard replicated params over "data"
    scan_layers: bool = True           # lax.scan over stacked layers
    remat: str = "full"                # none | full | dots_saveable
    shard_seq_decode: bool = False     # SP: shard long decode KV over "data"
    quantize_weights: bool = False     # int8 weight path (AMX->MXU analogue)
    optimizer_dtype: str = "float32"   # moments dtype; bf16 halves opt state
    # --- perf-iteration knobs (EXPERIMENTS.md §Perf) ---
    attention_chunk: int = 0           # >0: online-softmax over q chunks of
                                       # this size (never materialize s x s)
    loss_chunk: int = 0                # >0: CE loss over seq chunks (never
                                       # materialize [b, s, vocab] logits)
    dp_over_model: bool = False        # attn-free archs: run batch over the
                                       # model axis too (flat DP + FSDP)
    microbatches: int = 1              # gradient accumulation factor
    decode_cache_carry: bool = False   # decode: cache as scan CARRY with
                                       # per-layer in-place slice updates
                                       # instead of xs/ys full-cache streaming
    zero1: bool = False                # replicate params, shard ONLY the
                                       # optimizer moments over "data"
                                       # (ZeRO-1; for recurrent archs where
                                       # FSDP gathers land inside time scans)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense|moe|hybrid|ssm|encdec|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = True
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    ssm: Optional[SSMSpec] = None
    # hybrid (jamba): one attention layer per `attn_period` layers
    attn_period: int = 0
    moe_period: int = 0                # MoE FFN every `moe_period` layers
    # enc-dec (whisper)
    encoder_layers: int = 0
    decoder_layers: int = 0
    max_target_len: int = 448
    # modality stub: inputs are precomputed embeddings, not token ids
    embedding_inputs: bool = False
    # long-context capability (sub-quadratic mixer) -> long_500k runs
    sub_quadratic: bool = False
    parallel: ParallelConfig = dataclasses.field(default_factory=ParallelConfig)
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def params_count(self) -> Tuple[int, int]:
        """(total, active) parameter counts — used for MODEL_FLOPS=6ND."""
        d, v, L = self.d_model, self.vocab_size, self.num_layers
        hd = self.head_dim_
        emb = v * d

        def attn_params():
            if self.mla:
                m = self.mla
                return (d * m.q_lora_rank + m.q_lora_rank * self.num_heads * (m.nope_dim + m.rope_dim)
                        + d * (m.kv_lora_rank + m.rope_dim)
                        + m.kv_lora_rank * self.num_heads * (m.nope_dim + m.v_head_dim)
                        + self.num_heads * m.v_head_dim * d)
            return (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                    + self.num_heads * hd * d)

        def dense_ffn():
            return 3 * d * self.d_ff

        def moe_ffn(spec: MoESpec, active: bool):
            e = spec.top_k if active else spec.num_experts
            shared = 3 * d * spec.d_ff_expert * spec.num_shared_experts
            return 3 * d * spec.d_ff_expert * e + shared + d * spec.num_experts

        def ssm_params():
            s = self.ssm
            if s.kind == "rwkv6":
                # time-mix: r,k,v,g,o (5 d^2) + decay lora; channel-mix:
                # w_k (d,ff) + w_v (ff,d) + w_r (d^2)
                return 6 * d * d + 2 * d * s.lora_rank + 2 * d * self.d_ff
            di = s.expand * d
            dr = max(1, (d + 15) // 16)
            return d * 2 * di + di * (2 * s.d_state + dr) + dr * di + di * d

        total = active = emb
        nlayers = self.num_layers if not self.encoder_layers else (
            self.encoder_layers + self.decoder_layers)
        for i in range(nlayers):
            if self.family == "ssm":
                t = a = ssm_params()
            elif self.family == "hybrid":
                is_attn = self.attn_period and (i % self.attn_period == self.attn_period - 1)
                mix = attn_params() if is_attn else ssm_params()
                if self.moe and self.moe_period and (i % self.moe_period == self.moe_period - 1):
                    t = mix + moe_ffn(self.moe, False)
                    a = mix + moe_ffn(self.moe, True)
                else:
                    t = a = mix + dense_ffn()
            elif self.moe:
                t = attn_params() + moe_ffn(self.moe, False)
                a = attn_params() + moe_ffn(self.moe, True)
            else:
                t = a = attn_params() + dense_ffn()
                if self.encoder_layers and i < self.encoder_layers:
                    pass  # encoder layer: same dense shape (cross-attn adds below)
            if self.encoder_layers and i >= self.encoder_layers:
                t += attn_params()  # cross-attention
                a += attn_params()
            total += t
            active += a
        return total, active


# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step_kind: str   # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a cell is lowered (DESIGN.md §5 skips)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k needs sub-quadratic mixer"
    return True, ""


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs():
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def smoke_config(name: str) -> ModelConfig:
    """Tiny same-family config: few layers, narrow width, tiny vocab."""
    cfg = get_config(name)
    kw: dict = dict(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32",
        parallel=dataclasses.replace(cfg.parallel, scan_layers=True),
    )
    if cfg.family == "ssm":
        kw.update(num_layers=2, d_model=64, d_ff=128)
        kw["ssm"] = dataclasses.replace(cfg.ssm, head_dim=16, lora_rank=8, chunk=8)
    # smoke MoE runs dropless (high capacity): parity tests require that
    # prefill/decode see the same expert outputs as teacher-forced forward.
    if cfg.family == "hybrid":
        kw.update(num_layers=cfg.attn_period)  # one full group
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=4, d_conv=4, expand=2, chunk=8)
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                        d_ff_expert=64, capacity_factor=8.0)
    elif cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            first_k_dense=min(cfg.moe.first_k_dense, 1), capacity_factor=8.0)
    if cfg.mla is not None:
        kw["mla"] = MLASpec(q_lora_rank=32, kv_lora_rank=16, rope_dim=8,
                            nope_dim=16, v_head_dim=16)
        kw.update(num_heads=4, num_kv_heads=4, head_dim=16)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, decoder_layers=2, max_target_len=16)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
