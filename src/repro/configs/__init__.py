"""Architecture configs. One module per assigned architecture + the paper's
own Llama2 family. ``get_config(name)`` / ``list_configs()`` are the API."""

from repro.configs.base import (
    ModelConfig, ParallelConfig, ShapeConfig, SHAPES,
    get_config, list_configs, register, smoke_config,
)

# import for registration side-effects
from repro.configs import (  # noqa: F401
    whisper_small, deepseek_7b, qwen3_32b, deepseek_67b, mistral_nemo_12b,
    dbrx_132b, deepseek_v3_671b, jamba_v01_52b, rwkv6_3b, chameleon_34b,
    llama2,
)

__all__ = [
    "ModelConfig", "ParallelConfig", "ShapeConfig", "SHAPES",
    "get_config", "list_configs", "register", "smoke_config",
]
