"""whisper-small [audio]: enc-dec, 12L(+12L dec) d_model=768 12H d_ff=3072
vocab=51865. Conv frontend is a STUB: input_specs feed precomputed frame
embeddings. [arXiv:2212.04356; unverified]"""

from repro.configs import base


@base.register("whisper-small")
def config() -> base.ModelConfig:
    return base.ModelConfig(
        name="whisper-small",
        family="encdec",
        num_layers=12,
        encoder_layers=12,
        decoder_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        max_target_len=448,
        embedding_inputs=True,     # encoder consumes precomputed frames
        rope_theta=10000.0,        # (whisper uses sinusoidal; rope as stand-in)
        sub_quadratic=False,
        source="arXiv:2212.04356; unverified",
    )
