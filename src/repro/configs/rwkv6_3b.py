"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536.
Finch data-dependent decay. Constant state -> long_500k RUNS.
[arXiv:2404.05892; hf]"""

from repro.configs import base


@base.register("rwkv6-3b")
def config() -> base.ModelConfig:
    return base.ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        num_layers=32,
        d_model=2560,
        num_heads=40,          # 2560 / 64 rwkv heads
        num_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        ssm=base.SSMSpec(kind="rwkv6", head_dim=64, lora_rank=64),
        sub_quadratic=True,
        source="arXiv:2404.05892; hf",
    )
